"""ILP mapping benchmark (§III-D): solver runtime + optimality gap of the
greedy heuristic vs the exact solvers across layer sizes; dispatch-cycle
benefit of ILP load-balancing (the quantity the mapping actually optimizes)."""

from __future__ import annotations

import time

import numpy as np

from repro.core.mapping import (MappingProblem, solve_mapping,
                                solve_mapping_greedy, solve_mapping_reduced_ilp)
from repro.core.memories import build_event_memories


def bench_one(n_src, n_dest, m, n, density, seed=0):
    rng = np.random.default_rng(seed)
    w = rng.normal(size=(n_src, n_dest)).astype(np.float32)
    w[rng.random(w.shape) > density] = 0
    fanout = np.maximum((w != 0).sum(1) * 0.9, 1).astype(int)
    p = MappingProblem.from_weights(w, m, n, fanout=fanout)

    t0 = time.perf_counter()
    s_ilp = solve_mapping_reduced_ilp(p, time_limit=5.0)
    t_ilp = time.perf_counter() - t0
    t0 = time.perf_counter()
    s_gr = solve_mapping_greedy(p)
    t_gr = time.perf_counter() - t0

    # dispatch-cycle quality: total MEM_S&N rows (cycles) per solution
    rows_ilp = build_event_memories(w, s_ilp, m, n).n_rows
    rows_gr = build_event_memories(w, s_gr, m, n).n_rows
    return {
        "size": f"{n_src}x{n_dest}_M{m}N{n}",
        "ilp_assigned": s_ilp.n_assigned, "greedy_assigned": s_gr.n_assigned,
        "ilp_ms": t_ilp * 1e3, "greedy_ms": t_gr * 1e3,
        "ilp_rows": rows_ilp, "greedy_rows": rows_gr,
    }


def main():
    cases = [
        (64, 40, 10, 16, 0.5),
        (128, 64, 10, 16, 0.5),
        (200, 100, 20, 32, 0.4),
    ]
    for c in cases:
        r = bench_one(*c)
        gap = r["ilp_assigned"] - r["greedy_assigned"]
        print(f"mapping/{r['size']},ilp_ms={r['ilp_ms']:.1f},"
              f"greedy_ms={r['greedy_ms']:.1f},"
              f"assigned_gap={gap},"
              f"rows_ilp={r['ilp_rows']},rows_greedy={r['greedy_rows']}")


if __name__ == "__main__":
    main()
