"""ILP-machinery expert placement (beyond-paper, DESIGN.md)."""

import numpy as np
from _hypothesis_compat import given, settings, st

from repro.core.mapping.experts import place_experts, placement_peak_load


def test_balanced_placement_qwen3_shape(rng):
    """128 experts on 16 devices, 8 slots each (the qwen3 EP layout)."""
    load = rng.pareto(2.0, 128) + 0.1         # skewed router loads
    assign = place_experts(load, n_devices=16, slots_per_device=8)
    counts = np.bincount(assign, minlength=16)
    assert counts.max() <= 8
    assert (assign >= 0).all()
    peak = placement_peak_load(load, assign, 16)
    ideal = load.sum() / 16
    assert peak <= 1.35 * ideal + load.max()   # LPT bound


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 1000))
def test_placement_always_feasible(seed):
    rng = np.random.default_rng(seed)
    e = int(rng.integers(4, 33))
    d = int(rng.integers(2, 9))
    slots = int(np.ceil(e / d)) + int(rng.integers(0, 3))
    load = rng.random(e) + 0.01
    assign = place_experts(load, d, slots)
    counts = np.bincount(assign, minlength=d)
    assert counts.max() <= slots
    assert (assign >= 0).all()


def test_beats_naive_contiguous(rng):
    """Balanced placement beats the naive contiguous expert sharding under
    skewed load (the production default assigns experts round-robin)."""
    load = np.ones(32)
    load[:4] = 20.0                           # 4 hot experts
    naive = np.repeat(np.arange(4), 8)        # contiguous blocks of 8
    assign = place_experts(load, n_devices=4, slots_per_device=8)
    assert placement_peak_load(load, assign, 4) < \
        placement_peak_load(load, naive, 4)
