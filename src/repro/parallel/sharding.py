"""Logical-axis -> mesh-axis sharding rules (MaxText-style).

Weights and activations are annotated with *logical* axis names
(models/layers.py docstring); a :class:`ShardingRules` table maps them onto
physical mesh axes.  Rules degrade gracefully: a mapping is dropped when the
mesh lacks the axis or the dimension is not divisible by the axis size, so
the same model code runs on a 1-device CPU test, a 16x16 pod, or a 2x16x16
multi-pod mesh.

Conventions (production mesh ("pod","data","model")):
  batch        -> ("pod", "data")     pure DP across pods (DCN) and within pod
  weight embed -> "data"              FSDP / ZeRO-3: params+optimizer sharded,
                                      all-gathered per scanned layer
  heads/mlp/vocab/experts -> "model"  TP / EP over ICI
  cache_seq    -> "model"             sequence-parallel decode (flash-decode)
"""

from __future__ import annotations

import contextlib
import dataclasses
import threading
from typing import Any

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec


AxisMap = dict[str, Any]  # logical name -> mesh axis | tuple | None

TRAIN_RULES: AxisMap = {
    # weights
    "layers": None, "embed": "data", "heads": "model", "kv_heads": "model",
    "head_dim": None, "mlp": "model", "vocab": "model",
    # experts: EP over model; the per-expert d dim is FSDP-sharded over data
    # (without it qwen3's 227B expert params sit at 57 GB f32/device) — the
    # shard_map MoE's in_specs trigger the per-layer FSDP gather
    "experts": "model", "expert_mlp": "model", "expert_embed": "data",
    "ssm_inner": "model", "ssm_state": None, "ssm_heads": "model",
    "conv_width": None,
    # activations
    "act_batch": ("pod", "data"), "act_seq": None, "act_embed": None,
    "act_heads": "model", "act_kv_heads": "model", "act_head_dim": None,
    "act_mlp": "model", "act_vocab": "model",
    "act_experts": "model", "act_expert_cap": ("pod", "data"),
    "act_ssm_inner": "model", "act_ssm_state": None, "act_ssm_heads": "model",
    # kv cache (decode)
    "cache_batch": ("pod", "data"), "cache_seq": None, "cache_kv_heads": "model",
}

# decode: batch on data axes; baseline replicates cache seq (cache_seq=None),
# kv heads on model when divisible.  The SP flash-decode path (hillclimb)
# activates DECODE_RULES_SP instead.
DECODE_RULES: AxisMap = dict(TRAIN_RULES)

DECODE_RULES_SP: AxisMap = {**TRAIN_RULES,
                            "cache_seq": "model", "cache_kv_heads": None,
                            "act_kv_heads": None}

# MENAGE event-stream serving (engine/sharded_run.py): pure data parallelism.
# The batch of spike trains shards over the host mesh's data axes; the time
# axis stays local (the LIF scan is causal/stateful) and the neuron axis stays
# local (the control-memory pytree — MEM_E2A / MEM_S&N / A-SYN — is replicated
# on every device, exactly like the silicon replicates a full MX-NEURACORE
# chain per die).  The same divisibility fallback applies: a batch that the
# mesh can't split serves replicated instead of crashing.
SNN_SERVE_RULES: AxisMap = {
    "event_batch": ("pod", "data"),
    "event_time": None,
    "neuron": None,
}

# MENAGE sharded DP training (engine/snn_train.py): the spike batch shards
# over the same data axes as serving, while params and optimizer state stay
# replicated on every device (the evaluation models are tiny next to the
# transformer stack, so FSDP buys nothing) and per-shard gradients combine
# with a fixed-order fold — a deterministic psum that keeps the training
# trajectory bit-exact across mesh sizes.  The training layout is time-major
# ``[T, B, n_in]`` (the lax.scan axis first), hence event_time leads.
SNN_TRAIN_RULES: AxisMap = {
    "event_batch": ("pod", "data"),
    "event_time": None,
    "neuron": None,
    "snn_weight": None,     # params + Adam moments replicated
}


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    mesh: Mesh
    rules: AxisMap

    def spec(self, axes: tuple[str | None, ...],
             dims: tuple[int, ...] | None = None) -> PartitionSpec:
        """PartitionSpec for a tuple of logical axis names; drops mappings the
        mesh can't honor (missing axis / non-divisible dim)."""
        parts, used = [], set()
        for i, name in enumerate(axes):
            target = self.rules.get(name) if name else None
            if target is None:
                parts.append(None)
                continue
            tgt = tuple(t for t in ((target,) if isinstance(target, str) else target)
                        if t in self.mesh.axis_names and t not in used)
            if not tgt:
                parts.append(None)
                continue
            size = 1
            for t in tgt:
                size *= self.mesh.shape[t]
            if dims is not None and dims[i] % size != 0:
                # try a prefix that divides
                tgt2 = []
                size = 1
                for t in tgt:
                    if dims[i] % (size * self.mesh.shape[t]) == 0:
                        tgt2.append(t)
                        size *= self.mesh.shape[t]
                tgt = tuple(tgt2)
                if not tgt:
                    parts.append(None)
                    continue
            used.update(tgt)
            parts.append(tgt[0] if len(tgt) == 1 else tgt)
        return PartitionSpec(*parts)

    def sharding(self, axes: tuple[str | None, ...],
                 dims: tuple[int, ...] | None = None) -> NamedSharding:
        return NamedSharding(self.mesh, self.spec(axes, dims))


_local = threading.local()


def activate(mesh: Mesh, rules: AxisMap):
    """Context manager installing rules for `shard()` constraints."""

    @contextlib.contextmanager
    def ctx():
        prev = getattr(_local, "rules", None)
        _local.rules = ShardingRules(mesh, rules)
        try:
            with mesh:
                yield _local.rules
        finally:
            _local.rules = prev

    return ctx()


def current_rules() -> ShardingRules | None:
    return getattr(_local, "rules", None)


def active_mesh() -> Mesh | None:
    r = current_rules()
    return r.mesh if r else None


def logical_spec(axes, dims=None) -> PartitionSpec:
    r = current_rules()
    if r is None:
        return PartitionSpec()
    return r.spec(tuple(axes), dims)


def named_sharding(axes, dims=None) -> NamedSharding | None:
    r = current_rules()
    if r is None:
        return None
    return r.sharding(tuple(axes), dims)


def shard(x: jax.Array, *axes: str | None) -> jax.Array:
    """Annotate an activation with logical axes (no-op without active rules)."""
    r = current_rules()
    if r is None:
        return x
    return jax.lax.with_sharding_constraint(
        x, r.sharding(tuple(axes), tuple(x.shape)))


def tree_param_shardings(rules: ShardingRules, axes_tree, shapes_tree):
    """NamedSharding pytree for params given their logical axes + shapes."""
    return jax.tree.map(
        lambda ax, shp: rules.sharding(tuple(ax), tuple(shp.shape)),
        axes_tree, shapes_tree,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(e, (str, type(None))) for e in x))
