"""Table II reproduction: TOPS/W of Accel_1 (N-MNIST) and Accel_2
(CIFAR10-DVS) from the calibrated energy model driven by the cycle-level
dispatch simulator.

Flow = Algorithm 1: train (short, synthetic stand-in datasets) -> L1 prune
-> 8-bit quantize -> ILP map -> execute -> energy report.
For speed the SNN is trained briefly; energy depends on spike statistics,
not accuracy, and the synthetic sets match the paper's activity contrast
(CIFAR10-DVS busier than N-MNIST).
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs.menage_paper import (CIFAR_CONV, CIFAR_CONV_DATA,
                                        CIFAR_DATA, CIFAR_SNN, NMNIST_DATA,
                                        NMNIST_SNN)
from repro.core.accelerator import map_model, run
from repro.core.energy import ACCEL_1, ACCEL_2
from repro.core.prune import prune_pytree
from repro.core.quant import quantize_pytree
from repro.data.events import event_batches, synthetic_event_dataset
from repro.engine import SNNTrainConfig, model_for, train_snn_model
from repro.snn.conv import layer_specs


def _prepare(data_cfg, snn_cfg, train_steps: int, key):
    spikes, labels = synthetic_event_dataset(data_cfg, n_per_class=8, key=key)
    it = event_batches(spikes, labels, batch=16)
    params, _ = train_snn_model(model_for(snn_cfg), snn_cfg, it,
                                SNNTrainConfig(steps=train_steps, lr=1e-3,
                                               log_every=1000),
                                key=key, log_fn=lambda s: None)
    pruned, _ = prune_pytree(params, 0.5)
    _, dq = quantize_pytree(pruned)
    return [np.asarray(w) for w in dq], spikes


def measure(spec, data_cfg, snn_cfg, n_images: int = 4,
            train_steps: int = 30, seed: int = 0):
    key = jax.random.key(seed)
    weights, spikes = _prepare(data_cfg, snn_cfg, train_steps, key)
    model = map_model(weights, spec, lif=snn_cfg.lif)
    reports = []
    for i in range(n_images):
        res = run(model, spikes[i])
        reports.append(res.energy)
    tops_w = float(np.mean([r.tops_per_w for r in reports]))
    util = float(np.mean([r.utilization for r in reports]))
    ops = int(np.mean([r.total_ops for r in reports]))
    return {"accel": spec.name, "tops_per_w": tops_w, "utilization": util,
            "ops_per_image": ops,
            "rounds_per_layer": [len(l.rounds) for l in model.layers]}


def measure_conv(spec, data_cfg, conv_cfg, n_images: int = 2,
                 train_steps: int = 15, seed: int = 0):
    """Conv twin of :func:`measure`: train the spiking CNN, prune, lower to
    Conv2d/SumPool2d/Dense specs (shared weight-SRAM words), execute on the
    cycle-level oracle."""
    key = jax.random.key(seed)
    spikes, labels = synthetic_event_dataset(data_cfg, n_per_class=8, key=key)
    it = event_batches(spikes, labels, batch=16)
    params, _ = train_snn_model(model_for(conv_cfg), conv_cfg, it,
                                SNNTrainConfig(steps=train_steps, lr=1e-3,
                                               log_every=1000),
                                key=key, log_fn=lambda s: None)
    pruned, _ = prune_pytree(params, 0.5)
    model = map_model(layer_specs(pruned, conv_cfg), spec, lif=conv_cfg.lif)
    reports = [run(model, spikes[i]).energy for i in range(n_images)]
    return {"accel": spec.name,
            "tops_per_w": float(np.mean([r.tops_per_w for r in reports])),
            "utilization": float(np.mean([r.utilization for r in reports])),
            "ops_per_image": int(np.mean([r.total_ops for r in reports])),
            "rounds_per_layer": [len(l.rounds) for l in model.layers],
            "sram_bytes_per_layer": [l.weight_bytes for l in model.layers]}


def main(fast: bool = True, model: str = "mlp"):
    t0 = time.monotonic()
    rows = []
    paper = {"Accel1": 3.4, "Accel2": 12.1}
    if model in ("mlp", "both"):
        # NOTE: CIFAR10-DVS synthetic stand-in is spatially downsampled
        # (DESIGN.md §5) so the CPU-hosted simulation finishes; activity
        # statistics are preserved, layer widths are the paper's.
        r1 = measure(ACCEL_1, NMNIST_DATA, NMNIST_SNN,
                     n_images=2 if fast else 8)
        rows.append(("mlp", r1))
        r2 = measure(ACCEL_2, CIFAR_DATA, CIFAR_SNN,
                     n_images=1 if fast else 4, train_steps=15)
        rows.append(("mlp", r2))
    if model in ("conv", "both"):
        rc = measure_conv(ACCEL_2, CIFAR_CONV_DATA, CIFAR_CONV,
                          n_images=1 if fast else 4)
        rows.append(("conv", rc))
    for fam, r in rows:
        target = paper[r["accel"]]
        print(f"energy/{r['accel']}-{fam},{r['tops_per_w']:.3f},"
              f"paper={target},util={r['utilization']:.3f},"
              f"ops={r['ops_per_image']}")
    by_fam = {fam: r for fam, r in rows if r["accel"] == "Accel2"}
    if len(by_fam) == 2:
        print(f"energy/split,mlp={by_fam['mlp']['tops_per_w']:.3f},"
              f"conv={by_fam['conv']['tops_per_w']:.3f} TOPS/W on Accel2 "
              f"(Table II implies the MLP-vs-CNN split)")
    print(f"energy,elapsed,{time.monotonic()-t0:.1f}s")
    return [r for _, r in rows]


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--model", choices=("mlp", "conv", "both"),
                    default="mlp")
    args = ap.parse_args()
    main(fast=args.fast, model=args.model)
