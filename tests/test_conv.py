"""Conv layer specs, shared-weight lowering, and the spiking-CNN model.

Covers the conv tentpole end to end: unrolling math vs ``lax.conv``, the
one-stored-tap-many-rows SRAM sharing, training-graph / lowered-spec
agreement, and the acceptance case — a *trained* conv model (2 conv layers
+ dense head) on the synthetic CIFAR10-DVS stream executing bit-identically
on the numpy oracle and the batched engine for a full batch.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.accelerator import map_model, reference_forward, run_batch
from repro.core.energy import AcceleratorSpec
from repro.core.layers import Conv2d, Dense, SumPool2d, as_layer_spec
from repro.core.mapping import MappingError
from repro.core.lif import LIFParams
from repro.core.prune import prune_pytree
from repro.data.events import EventDatasetConfig, event_batches, \
    synthetic_event_dataset
from repro.engine import CONV_MODEL, SNNTrainConfig, train_snn_model
from repro.engine import batched_run as br
from repro.snn.conv import (ConvSNNConfig, conv_snn_forward, init_conv_snn,
                            layer_specs)

SPEC = AcceleratorSpec("test", n_cores=8, n_engines=4, n_caps=8,
                       weight_mem_bytes=1 << 16)


def _rand_kernel(rng, c_out, c_in, k, density=0.7):
    kern = rng.normal(0, 0.8, (c_out, c_in, k, k)).astype(np.float32)
    kern[rng.random(kern.shape) > density] = 0
    return kern


def test_conv2d_unroll_matches_lax_conv(rng):
    """x @ unroll(conv) == lax.conv for stride/pad combinations."""
    for stride, pad in [(1, 0), (1, 1), (2, 1), (3, 0)]:
        kern = _rand_kernel(rng, 3, 2, 3)
        conv = Conv2d(kernel=kern, in_shape=(2, 7, 7), stride=stride,
                      padding=pad)
        x = rng.random((4, 2, 7, 7)).astype(np.float32)
        ref = jax.lax.conv_general_dilated(
            jnp.asarray(x), jnp.asarray(kern), (stride, stride),
            [(pad, pad)] * 2, dimension_numbers=("NCHW", "OIHW", "NCHW"))
        got = x.reshape(4, -1) @ conv.unroll()
        np.testing.assert_allclose(
            got, np.asarray(ref).reshape(4, -1), atol=1e-5,
            err_msg=f"stride={stride} pad={pad}")
        assert conv.n_dest == int(np.prod(conv.out_shape))


def test_share_ids_name_kernel_taps(rng):
    """Every unrolled synapse's share id is its kernel tap; equal ids carry
    equal weights; id count == stored-tap count == unique_weight_bytes."""
    kern = _rand_kernel(rng, 2, 2, 3, density=0.6)
    conv = Conv2d(kernel=kern, in_shape=(2, 6, 6), stride=1, padding=1)
    w, ids = conv.unroll(), conv.share_ids()
    assert ids.shape == w.shape
    np.testing.assert_array_equal(ids >= 0, w != 0)
    flat_k = kern.reshape(-1)
    nz = ids >= 0
    np.testing.assert_array_equal(w[nz], flat_k[ids[nz]])
    assert len(np.unique(ids[nz])) == conv.unique_weight_bytes \
        == int((kern != 0).sum())
    # the unrolled synapse count dwarfs the stored taps — the whole point
    assert int(nz.sum()) > conv.unique_weight_bytes


def test_shared_sram_allocation(rng):
    """After mapping, each engine's A-SYN SRAM holds at most one word per
    kernel tap — rows share — while dense layers store one word per
    synapse, byte accounting matching both."""
    kern = _rand_kernel(rng, 3, 2, 3)
    conv = Conv2d(kernel=kern, in_shape=(2, 6, 6), stride=1, padding=1)
    dense = Dense(w=rng.normal(0, 0.7, (conv.n_dest, 6)).astype(np.float32))
    model = map_model([conv, dense], SPEC)
    cl, dl = model.layers
    assert cl.shared_weights and not dl.shared_weights
    # accounting is over the *quantized* stored tensors (rounding may zero
    # a small tap), never over unrolled synapses
    assert cl.weight_bytes == int((np.asarray(cl.layer_spec.kernel) != 0).sum())
    assert cl.weight_bytes <= int((kern != 0).sum())
    assert dl.weight_bytes == int((np.asarray(dl.layer_spec.w) != 0).sum())
    assert dl.weight_bytes <= int((np.asarray(dense.w) != 0).sum())
    for rnd in cl.rounds:
        t = rnd.tables
        used = int(t.sn_valid.sum())                 # synapses in this round
        words = t.weight_mem.shape[1]                # SRAM words per engine
        assert words <= cl.weight_bytes, \
            "an engine stores more words than the kernel has taps"
        assert used > words, "conv round shows no weight sharing"
    # physical allocation: one word per tap per engine per round that uses
    # it — what the budget assert actually guarantees fits
    assert cl.sram_bytes == sum(r.tables.n_weight_words for r in cl.rounds)
    assert cl.weight_bytes <= cl.sram_bytes \
        <= cl.weight_bytes * SPEC.n_engines * len(cl.rounds)
    assert dl.sram_bytes <= dl.weight_bytes   # dense: assigned synapses


def test_map_model_rejects_physical_sram_overflow(rng):
    """A conv layer can pass the unique-kernel-byte precheck yet exceed the
    core's SRAM once taps are replicated per engine/round — map_model must
    reject it (regression for the under-counting budget assert)."""
    kern = _rand_kernel(rng, 2, 1, 3, density=1.0)   # 18 unique taps
    conv = Conv2d(kernel=kern, in_shape=(1, 6, 6), stride=1, padding=0)
    tight = AcceleratorSpec("tight", n_cores=1, n_engines=4, n_caps=8,
                            weight_mem_bytes=20)     # 18 <= 20 precheck OK
    with pytest.raises(MappingError, match="round"):
        map_model([conv], tight)


def test_replay_coo_matches_dense_weights(rng):
    """The engine's O(nnz) COO replay and the oracle-grade dense replay
    describe the same synapses, bit for bit."""
    kern = _rand_kernel(rng, 2, 1, 3)
    conv = Conv2d(kernel=kern, in_shape=(1, 6, 6), stride=2, padding=1)
    model = map_model([conv], SPEC)
    for rnd in model.layers[0].rounds:
        n_local = len(rnd.neuron_ids)
        w_dense = rnd.tables.dense_weights(n_local)
        src, dest, vals = rnd.tables.replay_coo()
        w_coo = np.zeros_like(w_dense)
        np.add.at(w_coo, (src, dest), vals)
        np.testing.assert_array_equal(w_coo, w_dense)
        # each (src, dest) pair appears at most once
        assert len(set(zip(src.tolist(), dest.tolist()))) == len(src)


def test_sum_pool_is_depthwise_sum(rng):
    pool = SumPool2d((3, 4, 4), pool=2)
    assert pool.out_shape == (3, 2, 2)
    x = rng.random((2, 3, 4, 4)).astype(np.float32)
    got = (x.reshape(2, -1) @ pool.unroll()).reshape(2, 3, 2, 2)
    want = x.reshape(2, 3, 2, 2, 2, 2).sum(axis=(3, 5))
    np.testing.assert_allclose(got, want, rtol=1e-6)
    assert pool.unique_weight_bytes == 3 * 4   # c * pool^2 ones


def test_layer_specs_match_training_forward():
    """The lowered Conv2d/SumPool2d/Dense stack computes the training
    graph: reference_forward over unrolled specs == conv_snn_forward."""
    cfg = ConvSNNConfig(in_shape=(2, 8, 8), conv_channels=(4, 6),
                        num_steps=6, lif=LIFParams(beta=0.8, threshold=0.7))
    params = init_conv_snn(jax.random.key(0), cfg)
    specs = layer_specs(params, cfg)
    assert [type(s).__name__ for s in specs] == \
        ["Conv2d", "Conv2d", "Conv2d", "Conv2d", "Dense"]
    key = jax.random.key(1)
    spikes = (jax.random.uniform(key, (6, 3, cfg.n_in)) < 0.3
              ).astype(jnp.float32)
    _, outs = conv_snn_forward(params, spikes, cfg)
    for b in range(3):
        ref = reference_forward(specs, cfg.lif, np.asarray(spikes[:, b]))
        np.testing.assert_allclose(np.asarray(outs[:, b]), ref, atol=1e-5)


def test_map_model_rejects_shape_mismatch(rng):
    conv = Conv2d(kernel=_rand_kernel(rng, 2, 1, 3), in_shape=(1, 5, 5))
    bad_dense = Dense(w=rng.normal(0, 1, (7, 4)).astype(np.float32))
    with pytest.raises(ValueError, match="expects"):
        map_model([conv, bad_dense], SPEC)
    with pytest.raises(ValueError, match="2-D"):
        as_layer_spec(rng.normal(0, 1, (2, 2, 3, 3)))


def test_trained_conv_model_bit_exact_batch():
    """Acceptance: a trained >=2-conv + dense-head model on the synthetic
    CIFAR10-DVS stream maps via map_model and run_batched is bit-identical
    to the oracle for every sample in a batch of 8."""
    data = EventDatasetConfig.cifar10_dvs_like(down=16)   # 2 x 8 x 8
    cfg = ConvSNNConfig(in_shape=(2, 8, 8), conv_channels=(4, 8),
                        num_steps=10)
    key = jax.random.key(0)
    spikes, labels = synthetic_event_dataset(data, n_per_class=3, key=key)
    spikes = spikes[:, :cfg.num_steps]
    it = event_batches(spikes, labels, batch=8)
    params, hist = train_snn_model(
        CONV_MODEL, cfg, it, SNNTrainConfig(steps=6, log_every=1000),
        key=jax.random.key(1), log_fn=lambda s: None)
    assert np.isfinite(hist["loss"][-1])
    pruned, _ = prune_pytree(params, 0.5)
    specs = layer_specs(pruned, cfg)
    assert sum(isinstance(s, Conv2d) for s in specs) >= 2
    model = map_model(specs, SPEC, lif=cfg.lif)
    assert any(len(l.rounds) > 1 for l in model.layers), \
        "stack should exercise multi-round conv mapping"
    batch = spikes[:8]
    res = br.run_batched(model, batch)
    assert res.out_spikes.sum() >= 0
    for b, oracle in enumerate(run_batch(model, batch)):
        np.testing.assert_array_equal(res.out_spikes[b], oracle.out_spikes,
                                      err_msg=f"sample {b}")
        for li, (bs, os_) in enumerate(zip(res.sample_stats(b),
                                           oracle.per_layer_stats)):
            np.testing.assert_array_equal(bs.engine_ops, os_.engine_ops,
                                          err_msg=f"sample {b} layer {li}")
            np.testing.assert_array_equal(bs.cycles, os_.cycles,
                                          err_msg=f"sample {b} layer {li}")
