"""MoE: routing/dispatch correctness + shard_map == GSPMD baseline."""

import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.models.transformer import moe_ffn

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(script: str, devices: int = 8) -> str:
    env = dict(os.environ, PYTHONPATH="src")
    pre = (f'import os; os.environ["XLA_FLAGS"] = '
           f'"--xla_force_host_platform_device_count={devices}"\n')
    p = subprocess.run([sys.executable, "-c", pre + script],
                       capture_output=True, text=True, env=env, cwd=REPO,
                       timeout=600)
    assert p.returncode == 0, (p.stdout[-2000:], p.stderr[-4000:])
    return p.stdout


def _moe_params(key, cfg):
    d, e, f = cfg.d_model, cfg.n_experts, cfg.d_ff
    ks = jax.random.split(key, 4)
    return {
        "router": jax.random.normal(ks[0], (d, e)) * 0.1,
        "we_gate": jax.random.normal(ks[1], (e, d, f)) * 0.1,
        "we_up": jax.random.normal(ks[2], (e, d, f)) * 0.1,
        "we_down": jax.random.normal(ks[3], (e, f, d)) * 0.1,
    }


def test_moe_dense_equivalence_no_drops():
    """With capacity >= tokens, sort-dispatch MoE == the O(E) dense oracle
    sum_j gate_j * FFN_{e_j}(x)."""
    cfg = get_smoke_config("mixtral_8x7b")
    lp = _moe_params(jax.random.key(0), cfg)
    x = jax.random.normal(jax.random.key(1), (2, 8, cfg.d_model))
    y, aux = moe_ffn(x, lp, cfg, capacity_factor=float(cfg.n_experts))

    # dense oracle
    t = 16
    xf = x.reshape(t, cfg.d_model)
    probs = jax.nn.softmax(
        jnp.einsum("td,de->te", xf, lp["router"]).astype(jnp.float32), -1)
    gate, idx = jax.lax.top_k(probs, cfg.top_k)
    gate = gate / gate.sum(-1, keepdims=True)
    def ffn_e(e, v):
        g = jax.nn.silu(v @ lp["we_gate"][e])
        return (g * (v @ lp["we_up"][e])) @ lp["we_down"][e]
    want = jnp.zeros_like(xf)
    for ti in range(t):
        for j in range(cfg.top_k):
            want = want.at[ti].add(
                gate[ti, j] * ffn_e(int(idx[ti, j]), xf[ti]))
    np.testing.assert_allclose(np.asarray(y.reshape(t, -1)),
                               np.asarray(want), atol=2e-5, rtol=1e-4)


def test_moe_sharded_matches_baseline_tp_and_ep():
    """shard_map MoE (both TP-in-expert and EP modes) == single-device
    baseline, given no capacity drops."""
    out = _run("""
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get_smoke_config
from repro.models.transformer import moe_ffn
from repro.parallel.moe import moe_ffn_sharded

def params(key, cfg):
    d, e, f = cfg.d_model, cfg.n_experts, cfg.d_ff
    ks = jax.random.split(key, 4)
    return {"router": jax.random.normal(ks[0], (d, e)) * 0.1,
            "we_gate": jax.random.normal(ks[1], (e, d, f)) * 0.1,
            "we_up": jax.random.normal(ks[2], (e, d, f)) * 0.1,
            "we_down": jax.random.normal(ks[3], (e, f, d)) * 0.1}

for arch, mesh_shape in [("mixtral_8x7b", (2, 4)),    # 4 nmid E=4 -> EP
                         ("qwen3_moe_235b_a22b", (2, 4))]:  # E=8 % 4 == 0 -> EP
    cfg = get_smoke_config(arch)
    lp = params(jax.random.key(0), cfg)
    x = jax.random.normal(jax.random.key(1), (4, 8, cfg.d_model))
    want, aux_w = moe_ffn(x, lp, cfg, capacity_factor=float(cfg.n_experts))
    mesh = jax.make_mesh(mesh_shape, ("data", "model"))
    got, aux_g = jax.jit(lambda x, lp: moe_ffn_sharded(
        x, lp, cfg, mesh, capacity_factor=float(cfg.n_experts),
        batch_axes=("data",)))(x, lp)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=5e-5, rtol=1e-3)
    # aux is a per-shard estimator (mean of products != product of means):
    # standard EP behaviour; must agree to ~10%
    assert abs(float(aux_g) - float(aux_w)) / float(aux_w) < 0.1
    print("OK", arch)
""")
    assert out.count("OK") == 2


def test_moe_grads_flow():
    cfg = get_smoke_config("qwen3_moe_235b_a22b")
    lp = _moe_params(jax.random.key(0), cfg)
    x = jax.random.normal(jax.random.key(1), (2, 4, cfg.d_model))

    def loss(lp):
        y, aux = moe_ffn(x, lp, cfg)
        return (y ** 2).mean() + 0.01 * aux

    g = jax.grad(loss)(lp)
    for leaf in jax.tree.leaves(g):
        assert np.all(np.isfinite(np.asarray(leaf)))
    # router must receive gradient (through the gate values)
    assert float(jnp.abs(g["router"]).sum()) > 0
