"""Spiking MLPs — the paper's evaluation models (§IV-A, Table I).

  N-MNIST:      in -> 200 -> 100 -> 40  -> 10   (0.49 M params)
  CIFAR10-DVS:  in -> 1000 -> 500 -> 200 -> 100 -> 10  (33.4 M params)

Surrogate-gradient training (SNNTorch-style [31]) with rate decoding:
classification by output-layer spike counts; cross-entropy on the counts.
Time-major spike inputs ``[T, B, n_in]``; `lax.scan` over T.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core.lif import LIFParams, lif_step


@dataclasses.dataclass(frozen=True)
class SNNConfig:
    layer_sizes: tuple[int, ...]       # (in, h1, ..., out)
    lif: LIFParams = LIFParams(beta=0.9, threshold=1.0)
    num_steps: int = 25

    @staticmethod
    def nmnist(n_in: int = 2 * 34 * 34) -> "SNNConfig":
        return SNNConfig(layer_sizes=(n_in, 200, 100, 40, 10))

    @staticmethod
    def cifar10_dvs(n_in: int = 2 * 128 * 128) -> "SNNConfig":
        return SNNConfig(layer_sizes=(n_in, 1000, 500, 200, 100, 10))


def init_snn(key: jax.Array, cfg: SNNConfig) -> list[jax.Array]:
    """Kaiming-ish init; weights only (the hardware has no bias path)."""
    params = []
    sizes = cfg.layer_sizes
    for i in range(len(sizes) - 1):
        key, sub = jax.random.split(key)
        w = jax.random.normal(sub, (sizes[i], sizes[i + 1])) * jnp.sqrt(2.0 / sizes[i])
        params.append(w)
    return params


def snn_forward(params: list[jax.Array], spikes: jax.Array, cfg: SNNConfig):
    """spikes: [T, B, n_in] -> (out_counts [B, n_out], out_spikes [T, B, n_out])."""

    def step(carry, s_t):
        vs = carry
        x = s_t
        new_vs = []
        for w, v in zip(params, vs):
            i_t = x @ w
            v2, x = lif_step(v, i_t, cfg.lif)
            new_vs.append(v2)
        return new_vs, x

    batch = spikes.shape[1]
    v0 = [jnp.zeros((batch, w.shape[1])) for w in params]
    _, out_spikes = jax.lax.scan(step, v0, spikes)
    return out_spikes.sum(axis=0), out_spikes


def snn_forward_batch_major(params: list[jax.Array], spikes_bt: jax.Array,
                            cfg: SNNConfig):
    """:func:`snn_forward` for batch-major ``[B, T, n_in]`` spike rasters —
    the batched accelerator engine's layout (`repro.engine.batched_run`).
    Returns ``(out_counts [B, n_out], out_spikes [B, T, n_out])``."""
    counts, out = snn_forward(params, jnp.swapaxes(spikes_bt, 0, 1), cfg)
    return counts, jnp.swapaxes(out, 0, 1)


def snn_loss(params, spikes, labels, cfg: SNNConfig):
    counts, _ = snn_forward(params, spikes, cfg)
    logits = counts  # rate code: counts are the logits
    logp = jax.nn.log_softmax(logits)
    loss = -jnp.take_along_axis(logp, labels[:, None], axis=1).mean()
    acc = (logits.argmax(-1) == labels).mean()
    return loss, acc


# Training lives in the unified engine path: repro.engine.snn_train
# (train_snn_model with MLP_MODEL / model_for(cfg)) — sharded DP, dynamic
# lr, checkpoint/elastic/straggler machinery.  This module only defines the
# model: init / forward / loss.
